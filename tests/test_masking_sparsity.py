"""Binary-mask encoding + pre/post-compute sparsity vs the paper's
Algorithm 1 oracle (hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core.masking import (
    compression_ratio,
    mask_decode,
    mask_encode,
    pack_mask_bits,
    tile_occupancy,
    unpack_mask_bits,
)
from repro.core.sparsity import apply_joint_mask, precompute_sparsity, sparse_dot
from repro.kernels.mask_compress.ref import (
    mask_pack_reference,
    precompute_module_reference,
)


def sparse_vec(seed: int, n: int, sparsity: float) -> jnp.ndarray:
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (n,))
    keep = jax.random.uniform(jax.random.fold_in(key, 1), (n,)) > sparsity
    return v * keep


@given(st.integers(0, 10_000), st.integers(1, 300), st.floats(0.0, 1.0))
def test_mask_roundtrip(seed, n, sparsity):
    x = sparse_vec(seed, n, sparsity)
    mv = mask_encode(x)
    np.testing.assert_allclose(np.asarray(mask_decode(mv)), np.asarray(x))
    # zero-free invariant: live values are exactly the non-zeros, in order
    nnz = int(mv.nnz)
    np.testing.assert_allclose(
        np.asarray(mv.values[:nnz]), np.asarray(x[x != 0.0]))
    assert not np.any(np.asarray(mv.values[nnz:]))


@given(st.integers(0, 10_000), st.integers(1, 200))
def test_pack_unpack(seed, n):
    bits = jax.random.uniform(jax.random.PRNGKey(seed), (n,)) > 0.5
    words = pack_mask_bits(bits)
    np.testing.assert_array_equal(np.asarray(unpack_mask_bits(words, n)), np.asarray(bits))


@given(st.integers(0, 10_000), st.integers(1, 128), st.floats(0.2, 0.9), st.floats(0.2, 0.9))
def test_precompute_module_matches_algorithm1(seed, n, sa, sw):
    """The vectorized pre-compute sparsity module == the element-serial
    Algorithm 1 + zero-collapse oracle, for both operands."""
    a = sparse_vec(seed, n, sa)
    w = sparse_vec(seed + 1, n, sw)
    m = precompute_sparsity(mask_encode(a), mask_encode(w))
    a_ref, w_ref, out_bits = precompute_module_reference(np.asarray(a), np.asarray(w))
    np.testing.assert_allclose(np.asarray(m.a_values), a_ref, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m.w_values), w_ref, rtol=1e-6)
    assert int(m.n_matched) == int(out_bits.sum())


@given(st.integers(0, 10_000), st.integers(1, 256))
def test_sparse_dot_equals_dense(seed, n):
    a = sparse_vec(seed, n, 0.6)
    w = sparse_vec(seed + 7, n, 0.5)
    assert np.allclose(float(sparse_dot(mask_encode(a), mask_encode(w))),
                       float(jnp.dot(a, w)), atol=1e-4)


def test_fig5_worked_example():
    """Paper Fig. 5: 16 elements, 6 non-zero, 16-bit values -> 112 bits
    total, compression 256/112 = 2.29x."""
    x = jnp.zeros((16,)).at[jnp.asarray([0, 2, 5, 9, 11, 14])].set(3.0)
    mv = mask_encode(x)
    assert int(mv.nnz) == 6
    ratio = float(compression_ratio(mv, 16))
    assert abs(ratio - 256 / 112) < 1e-5


@given(st.integers(0, 1000))
def test_joint_mask_preserves_products(seed):
    a = sparse_vec(seed, 64, 0.5)
    w = sparse_vec(seed + 3, 64, 0.5)
    af, wf = apply_joint_mask(a, w)
    np.testing.assert_allclose(np.asarray(af * wf), np.asarray(a * w), rtol=1e-6)


def test_tile_occupancy():
    x = jnp.zeros((4, 8)).at[0, 0].set(1.0).at[3, 7].set(2.0)
    occ = tile_occupancy(x, 2, 4)
    np.testing.assert_array_equal(np.asarray(occ),
                                  [[True, False], [False, True]])


def test_mask_pack_kernel_matches_reference():
    x = np.asarray(sparse_vec(0, 8 * 1024, 0.5)).reshape(8, 1024)
    from repro.kernels.mask_compress.mc_kernel import mask_pack_pallas

    got = np.asarray(mask_pack_pallas(jnp.asarray(x), interpret=True))
    np.testing.assert_array_equal(got, mask_pack_reference(x))
