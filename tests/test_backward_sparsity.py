"""Sparsity-aware backward pass: gradient parity of the custom_vjp masked
kernels against the dense ref gradient, for every registered
``masked_matmul_dx`` / ``masked_matmul_dw`` implementation runnable on
this backend, plus the StepConfig/launch threading and the end-to-end
stash/masked conv+fc acceptance check (ISSUE 3)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spring_ops import (
    BACKWARD_SPARSITY_CHOICES,
    QUANT_SPARSE,
    KeyGen,
    SpringConfig,
    spring_conv2d,
    spring_matmul,
)
from repro.kernels import registry
from repro.kernels.masked_matmul.backward import (
    masked_matmul_dw,
    masked_matmul_dx,
    sparsity_probe,
)
from repro.kernels.masked_matmul.ops import masked_matmul

# every backward impl runnable on this backend (pallas is TPU-only)
BWD_IMPLS = sorted(
    name for name, k in registry.impls("masked_matmul_dx").items()
    if k.available()
)

DENSITIES = [0.0, 0.1, 0.5, 1.0]
# (M, K, N): square, non-square, tile-unaligned
SHAPES = [(128, 128, 128), (100, 70, 50), (64, 200, 96)]
FORMATS = [(4, 16), (2, 6)]  # fp32-grid Q4.16 and a reduced-precision grid


def _sparse(seed: int, shape, density: float) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, shape) * 0.1
    keep = jax.random.uniform(jax.random.fold_in(key, 1), shape) < density
    return v * keep


# ---------------------------------------------------------------------------
# Registry completeness: an unregistered backward impl must fail here.
# ---------------------------------------------------------------------------


@pytest.mark.grad_parity
def test_backward_ops_registered_with_full_impl_ladder():
    """Every forward masked_matmul impl has a same-named dx and dw impl,
    and both backward ops carry parity examples so the registry-generated
    harness (tests/test_kernel_registry.py, bench --smoke) covers them."""
    fwd = set(registry.impls("masked_matmul"))
    # every forward backend has a matching backward impl (the backward
    # additionally registers the occupancy-gated jnp lowering)
    assert fwd <= set(registry.impls("masked_matmul_dx"))
    assert fwd <= set(registry.impls("masked_matmul_dw"))
    assert set(registry.impls("masked_matmul_dx")) == \
        set(registry.impls("masked_matmul_dw"))
    assert registry.op_spec("masked_matmul_dx").examples is not None
    assert registry.op_spec("masked_matmul_dw").examples is not None
    # and they show up in the generated parity sweep on this backend
    pairs = set(registry.parity_pairs())
    for op in ("masked_matmul_dx", "masked_matmul_dw"):
        for name, k in registry.impls(op).items():
            if name != "ref" and k.available() and k.parity:
                assert (op, name) in pairs, f"({op}, {name}) not parity-swept"


# ---------------------------------------------------------------------------
# Gradient parity: custom_vjp path vs jax.grad of the pure dense path.
# ---------------------------------------------------------------------------


@pytest.mark.grad_parity
@pytest.mark.parametrize("impl", BWD_IMPLS)
@pytest.mark.parametrize("density", DENSITIES)
def test_grad_parity_all_shapes_and_formats(impl, density):
    """jax.grad through masked_matmul(backward=impl) == jax.grad through
    the dense ref matmul, across shapes and Q(il,fl) formats.  The ReLU
    in the loss makes the cotangent mask-structured (Sarma et al.)."""
    for m, k, n in SHAPES:
        for il, fl in FORMATS:
            x = _sparse(m * 31 + k, (m, k), density)
            w = _sparse(n * 17 + k, (k, n), density if density else 0.5)

            def loss_vjp(x, w):
                y = masked_matmul(x, w, il=il, fl=fl, apply_sr=False,
                                  impl="ref", backward=impl)
                return jnp.sum(jax.nn.relu(y) ** 2)

            def loss_dense(x, w):
                return jnp.sum(jax.nn.relu(
                    jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))) ** 2)

            gx, gw = jax.grad(loss_vjp, argnums=(0, 1))(x, w)
            rx, rw = jax.grad(loss_dense, argnums=(0, 1))(x, w)
            np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                                       rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(gw), np.asarray(rw),
                                       rtol=1e-5, atol=1e-6)


@pytest.mark.grad_parity
@pytest.mark.parametrize("impl", BWD_IMPLS)
def test_grad_parity_under_jit_and_auto(impl):
    x = _sparse(0, (96, 64), 0.5)
    w = _sparse(1, (64, 80), 0.5)

    def loss(x, w, bwd):
        y = masked_matmul(x, w, apply_sr=False, impl="ref", backward=bwd)
        return jnp.sum(y ** 2)

    ref = jax.grad(lambda x, w: jnp.sum(jnp.dot(x, w) ** 2),
                   argnums=(0, 1))(x, w)
    for bwd in (impl, "auto"):
        got = jax.jit(jax.grad(lambda x, w: loss(x, w, bwd),
                               argnums=(0, 1)))(x, w)
        for g, r in zip(got, ref):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                       rtol=1e-5, atol=1e-6)


@pytest.mark.grad_parity
def test_backward_dispatch_counts_attribute_impl():
    """The dx/dw resolutions show up in dispatch_counts under the pinned
    impl — backward backend choices are attributable, like forward ones."""
    x, w = _sparse(2, (64, 64), 0.5), _sparse(3, (64, 64), 0.5)
    registry.reset_dispatch_counts()
    jax.grad(lambda x: jnp.sum(masked_matmul(
        x, w, apply_sr=False, impl="ref", backward="interpret") ** 2))(x)
    counts = registry.dispatch_counts()
    assert counts["masked_matmul_dx"] == {"interpret": 1}
    assert counts["masked_matmul_dw"] == {"interpret": 1}


@pytest.mark.grad_parity
def test_bad_backward_pin_fails_at_call_site():
    x, w = _sparse(4, (64, 64), 0.5), _sparse(5, (64, 64), 0.5)
    assert jax.default_backend() != "tpu"
    with pytest.raises(ValueError, match="not available"):
        masked_matmul(x, w, backward="pallas")
    with pytest.raises(ValueError, match="unknown kernel impl"):
        masked_matmul(x, w, backward="cuda")


# ---------------------------------------------------------------------------
# spring_matmul / spring_conv2d routing under SpringConfig.backward_sparsity.
# ---------------------------------------------------------------------------


def _cfgs(bwd: str):
    on = dataclasses.replace(QUANT_SPARSE, backward_sparsity=bwd)
    off = dataclasses.replace(QUANT_SPARSE, backward_sparsity="none")
    return on, off


@pytest.mark.grad_parity
@pytest.mark.parametrize("impl", BWD_IMPLS)
def test_spring_matmul_backward_matches_dense_autodiff(impl):
    """Forward numerics are bit-identical between backward_sparsity=impl
    and "none" (both lower to the dense fp32 matmul + STE epilogue on
    CPU), and the sparsity-aware gradient is allclose to autodiff."""
    on, off = _cfgs(impl)
    x = jax.nn.relu(_sparse(6, (64, 48), 0.5) * 10)
    w = _sparse(7, (48, 32), 1.0)

    def loss(cfg):
        def f(x, w):
            y = spring_matmul(x, w, cfg, KeyGen(jax.random.PRNGKey(11)))
            return jnp.sum(jax.nn.relu(y) ** 2)
        return f

    y_on = spring_matmul(x, w, on, KeyGen(jax.random.PRNGKey(11)))
    y_off = spring_matmul(x, w, off, KeyGen(jax.random.PRNGKey(11)))
    np.testing.assert_array_equal(np.asarray(y_on), np.asarray(y_off))

    g_on = jax.grad(loss(on), argnums=(0, 1))(x, w)
    g_off = jax.grad(loss(off), argnums=(0, 1))(x, w)
    for a, b in zip(g_on, g_off):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.grad_parity
def test_spring_conv2d_backward_matches_dense_autodiff():
    """Both conv backward GEMMs (dX via dilated cotangent patches, dW via
    im2col of the stashed activation) match the dense conv VJP."""
    on, off = _cfgs("interpret")
    x = jax.nn.relu(_sparse(8, (2, 12, 12, 8), 0.5) * 10)
    w = _sparse(9, (3, 3, 8, 16), 1.0)

    for stride, padding in [((1, 1), "SAME"), ((2, 2), "SAME"), ((1, 1), "VALID")]:
        def loss(cfg):
            def f(x, w):
                y = spring_conv2d(x, w, cfg, KeyGen(jax.random.PRNGKey(13)),
                                  stride=stride, padding=padding)
                return jnp.sum(jax.nn.relu(y) ** 2)
            return f

        g_on = jax.grad(loss(on), argnums=(0, 1))(x, w)
        g_off = jax.grad(loss(off), argnums=(0, 1))(x, w)
        for a, b in zip(g_on, g_off):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5,
                atol=1e-5 * float(np.max(np.abs(np.asarray(b))) + 1.0))


@pytest.mark.grad_parity
def test_grouped_conv_falls_back_to_dense_autodiff():
    """Depthwise convs keep the dense VJP (patch matrices interleave
    groups) — gradients must still flow and match."""
    on, off = _cfgs("auto")
    x = jax.nn.relu(_sparse(10, (2, 8, 8, 8), 0.5) * 10)
    w = _sparse(11, (3, 3, 1, 8), 1.0)

    def loss(cfg):
        def f(x, w):
            y = spring_conv2d(x, w, cfg, KeyGen(jax.random.PRNGKey(17)),
                              feature_group_count=8)
            return jnp.sum(y ** 2)
        return f

    g_on = jax.grad(loss(on), argnums=(0, 1))(x, w)
    g_off = jax.grad(loss(off), argnums=(0, 1))(x, w)
    for a, b in zip(g_on, g_off):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_spring_config_validates_backward_sparsity():
    for name in BACKWARD_SPARSITY_CHOICES:
        SpringConfig(backward_sparsity=name)
    with pytest.raises(ValueError, match="backward_sparsity"):
        SpringConfig(backward_sparsity="cuda")


# ---------------------------------------------------------------------------
# End-to-end acceptance: stash/masked conv+fc model, backward_sparsity
# pinned to the tile-skipping kernel, vs the dense ref gradient.
# ---------------------------------------------------------------------------


@pytest.mark.grad_parity
def test_conv_fc_model_grad_parity_with_stash():
    """jax.grad through a stash/masked conv+fc model with
    backward_sparsity="interpret" (the CPU stand-in for "pallas") is
    allclose (rtol 1e-5) to the dense ref gradient, with the memstash
    compressed-activation stash active at every conv/fc point."""
    from repro.memstash.config import MemstashConfig
    from repro.models.cnn import ParamStore, conv, fc
    from repro.models.layers import SpringContext

    def model(store, ctx, x):
        h = conv(store, ctx, "c1", x, 8, k=3)
        h = conv(store, ctx, "c2", h, 8, k=3, stride=2)
        h = h.reshape(h.shape[0], -1)
        h = fc(store, ctx, "f1", h, 32, relu=True)
        return fc(store, ctx, "f2", h, 10)

    key = jax.random.PRNGKey(0)
    x = jax.nn.relu(jax.random.normal(key, (2, 8, 8, 3)))
    init_store = ParamStore(jax.random.fold_in(key, 1))
    model(init_store, SpringContext(), x)
    params = init_store.params

    def loss(params, bwd):
        cfg = dataclasses.replace(QUANT_SPARSE, backward_sparsity=bwd)
        ctx = SpringContext(cfg=cfg, keys=KeyGen(jax.random.PRNGKey(2)),
                            memstash=MemstashConfig(policy="stash"))
        assert ctx.backward_sparsity() == bwd
        y = model(ParamStore(key, params), ctx, x)
        return jnp.mean(y ** 2)

    g_sparse = jax.grad(lambda p: loss(p, "interpret"))(params)
    g_ref = jax.grad(lambda p: loss(p, "none"))(params)
    for name in params:
        np.testing.assert_allclose(
            np.asarray(g_sparse[name]), np.asarray(g_ref[name]),
            rtol=1e-5, atol=1e-6, err_msg=name)


@pytest.mark.grad_parity
def test_step_config_threads_backward_sparsity_into_train_step():
    """StepConfig.backward_sparsity reaches the spring config the train
    step builds its contexts from (the --backward-sparsity CLI path)."""
    from repro.runtime.train import StepConfig, _spring_for

    cfg = StepConfig(spring=QUANT_SPARSE, backward_sparsity="interpret")
    assert _spring_for(cfg).backward_sparsity == "interpret"
    # default (None): inherit the SpringConfig switch untouched, both for
    # the "auto" default and for an explicitly-disabled spring config
    cfg2 = StepConfig(spring=QUANT_SPARSE)
    assert _spring_for(cfg2) is QUANT_SPARSE
    off = dataclasses.replace(QUANT_SPARSE, backward_sparsity="none")
    assert _spring_for(StepConfig(spring=off)).backward_sparsity == "none"


@pytest.mark.grad_parity
def test_sparsity_probe_reports_nonzero_backward_skip():
    """The dry-run's eager probe: at 50% tile-granular density the
    backward GEMMs skip a nonzero fraction of MXU grid steps (the
    acceptance criterion's dryrun JSON field)."""
    p = sparsity_probe(density=0.5, size=256)
    assert p["forward_tile_skip"] is not None and p["forward_tile_skip"] > 0.0
    assert p["backward_tile_skip"] is not None and p["backward_tile_skip"] > 0.0
    assert p["backward_tile_skip_dx"] > 0.0
    assert p["backward_tile_skip_dw"] > 0.0
    # denser operands skip less
    p_dense = sparsity_probe(density=1.0, size=256)
    assert p_dense["backward_tile_skip"] <= p["backward_tile_skip"]


@pytest.mark.grad_parity
def test_measured_backward_skip_feeds_perfmodel():
    """measured_backward_skip_fraction -> spring_eval: the training-time
    compute term scales as fwd + 2x bwd with independent skip fractions."""
    from repro.models.cnn import LayerRecord
    from repro.perfmodel.spring_model import (
        measured_backward_skip_fraction,
        spring_eval,
    )

    x = jnp.zeros((256, 256)).at[:128, :128].set(1.0)
    w = jnp.ones((256, 256))
    with registry.record_kernel_metrics():
        pass
    with registry.record_kernel_metrics() as rows:
        jax.grad(lambda x: jnp.sum(masked_matmul(
            x, w, apply_sr=False, impl="ref", backward="auto") ** 2))(x)
    bskip = measured_backward_skip_fraction(rows)
    assert bskip is not None and 0.0 <= bskip < 1.0
    assert measured_backward_skip_fraction([]) is None

    rec = LayerRecord(kind="fc", name="l", macs=10**12,
                      in_elems=10, w_elems=10, out_elems=10)
    base = spring_eval([rec], 1, training=True,
                       act_sparsity=0.0, w_sparsity=0.0)
    meas = spring_eval([rec], 1, training=True, act_sparsity=0.0,
                       w_sparsity=0.0, backward_skip_fraction=0.5)
    # fwd 1x unscaled + bwd 2x at (1-0.5): 2/3 of the dense-training time
    np.testing.assert_allclose(meas.time_s, base.time_s * (2.0 / 3.0),
                               rtol=1e-6)
