"""Direct unit tests for the HLO collective-byte accounting that feeds
the dry-run attribution spine: ``launch.collective_attribution.attribute``
(named-scope buckets, the unattributed path of ``_LINE``) and
``launch.hlo_analysis.collective_bytes`` (-start/-done pairing,
``bf16_correct`` payload halving) — on a fixed HLO snippet shaped like
what ``jax.jit`` + ``shard_map`` actually emit for the spring-mesh
packed collectives, so a regex regression can't silently zero the
roofline collectives table again.
"""

import pytest

from repro.launch.collective_attribution import _LINE, attribute
from repro.launch.hlo_analysis import collective_bytes

pytestmark = pytest.mark.mesh

# Captured-by-hand module: two packed all-gathers (values f32, mask
# words u32), a dense bf16 reference gather, an unattributed all-reduce
# (no metadata at all), an async reduce-scatter pair (-start carries the
# tuple shape and must be counted exactly once; -done must be skipped),
# and a non-collective dot that no pass may count.
HLO = """\
HloModule jit_step, entry_computation_layout={(f32[1,512]{1,0})->f32[4,512]{1,0}}

ENTRY %main.42 (p.1: f32[1,512]) {
  %p.1 = f32[1,512]{1,0} parameter(0)
  %all-gather.1 = f32[4,512]{1,0} all-gather(f32[1,512]{1,0} %p.1), replica_groups={{0,1,2,3}}, dimensions={0}, metadata={op_name="jit(step)/packed_all_gather/all_gather[axis_name=data]" source_file="collectives.py" source_line=210}
  %all-gather.2 = u32[4,16]{1,0} all-gather(u32[1,16]{1,0} %w.1), replica_groups={{0,1,2,3}}, dimensions={0}, metadata={op_name="jit(step)/packed_all_gather/all_gather[axis_name=data]"}
  %all-gather.7 = bf16[4,256]{1,0} all-gather(bf16[1,256]{1,0} %v.1), replica_groups={{0,1,2,3}}, dimensions={0}, metadata={op_name="jit(step)/dense_all_gather/all_gather"}
  %all-reduce.3 = f32[128]{0} all-reduce(f32[128]{0} %x.1), replica_groups={}, to_apply=%region_0.9
  %reduce-scatter-start.4 = (f32[2048]{0}, f32[512]{0}) reduce-scatter-start(f32[2048]{0} %g.1), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%region_1.13, metadata={op_name="jit(step)/packed_reduce_scatter/reduce_scatter"}
  %reduce-scatter-done.5 = f32[512]{0} reduce-scatter-done((f32[2048]{0}, f32[512]{0}) %reduce-scatter-start.4), metadata={op_name="jit(step)/packed_reduce_scatter/reduce_scatter"}
  ROOT %dot.6 = f32[64,64]{1,0} dot(f32[64,32]{1,0} %a.1, f32[32,64]{1,0} %b.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="jit(step)/dot_general[dimension_numbers=(((1,), (0,)), ((), ()))]"}
}
"""


def test_line_regex_captures_metadata_and_unattributed_path():
    attributed = ('%all-gather.1 = f32[4,512]{1,0} all-gather(f32[1,512]{1,0}'
                  ' %p.1), metadata={op_name="jit(step)/packed_all_gather/ag"'
                  ' source_file="c.py"}')
    m = _LINE.match(attributed)
    assert m.group(2) == "all-gather"
    assert m.group(3) == "jit(step)/packed_all_gather/ag"
    # metadata without op_name (and no metadata at all) both land in the
    # optional third group as None — the "(unattributed)" bucket
    for bare in (
        "%all-reduce.3 = f32[128]{0} all-reduce(f32[128]{0} %x.1)",
        "%all-reduce.3 = f32[128]{0} all-reduce(f32[128]{0} %x.1), "
        'metadata={source_file="x.py" source_line=3}',
    ):
        m = _LINE.match(bare)
        assert m.group(2) == "all-reduce"
        assert m.group(3) is None
    # tuple result shapes (async -start ops) capture the whole tuple
    m = _LINE.match("%reduce-scatter-start.4 = (f32[2048]{0}, f32[512]{0}) "
                    "reduce-scatter-start(f32[2048]{0} %g.1)")
    assert m.group(1) == "(f32[2048]{0}, f32[512]{0})"
    assert m.group(2) == "reduce-scatter-start"


def test_attribute_buckets_mesh_collectives():
    out = attribute(HLO)
    assert out["all-gather"] == {
        "mesh-packed-gather:f32": 4 * 512 * 4,
        "mesh-packed-gather:u32": 4 * 16 * 4,
        "mesh-dense-gather:bf16": 4 * 256 * 2,
    }
    # no metadata at all -> the unattributed bucket, dtype still sniffed
    assert out["all-reduce"] == {"(unattributed):f32": 128 * 4}
    # -start counted (full tuple: operand staging + result), -done skipped
    assert out["reduce-scatter"] == {
        "mesh-packed-reduce:f32": (2048 + 512) * 4,
    }
    # the dot contributes to no collective kind
    assert set(out) == {"all-gather", "all-reduce", "reduce-scatter"}


def test_collective_bytes_start_done_pairing_and_totals():
    out = collective_bytes(HLO)
    ag = 4 * 512 * 4 + 4 * 16 * 4 + 4 * 256 * 2
    ar = 128 * 4
    rs = (2048 + 512) * 4
    assert out["all-gather"] == ag
    assert out["all-reduce"] == ar
    assert out["reduce-scatter"] == rs
    assert out["count"] == 5  # the -done line must not double-count
    assert out["total"] == ag + ar + rs
    assert out["total_raw_f32"] == out["total"]


def test_collective_bytes_bf16_correct_halves_f32_payloads():
    out = collective_bytes(HLO, bf16_correct=True)
    # f32 payloads re-counted at 2 bytes/elem; u32 masks and native bf16
    # untouched; the raw f32 total is preserved alongside
    assert out["all-gather"] == 4 * 512 * 2 + 4 * 16 * 4 + 4 * 256 * 2
    assert out["all-reduce"] == 128 * 2
    assert out["reduce-scatter"] == (2048 + 512) * 2
    assert out["total_raw_f32"] == collective_bytes(HLO)["total"]
