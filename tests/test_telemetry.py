"""spring-trace seals (ISSUE 6).

Four contracts:

  1. the quantile sketch is mergeable (associative/commutative), exact
     under small n, and rank-accurate within its alpha bound past the
     exact phase — hypothesis properties;
  2. the MetricsRegistry snapshot/reset/restore API isolates global
     counter state (and the kernel dispatch counters ride on it);
  3. exported traces satisfy the Chrome trace-event schema and carry the
     tick/step span taxonomy;
  4. the parity seal: train losses and serve tokens are bit-identical
     with telemetry on vs off (enabling measurement must never change
     what is computed), and engine results carry latency attribution.
"""

import dataclasses
import json
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.telemetry import (
    MetricsRegistry,
    QuantileSketch,
    SpanTracer,
    TelemetryConfig,
    validate_chrome_trace,
)
from repro.telemetry.metrics import prometheus_from_snapshot, render_snapshot_table

pytestmark = pytest.mark.telemetry

# -- 1. quantile sketch properties -------------------------------------------

finite = st.floats(min_value=-1e9, max_value=1e9,
                   allow_nan=False, allow_infinity=False)


def _sk(values, alpha=0.01, max_exact=128):
    return QuantileSketch(alpha=alpha, max_exact=max_exact).update(values)


@given(st.lists(finite, max_size=60), st.lists(finite, max_size=60),
       st.lists(finite, max_size=60))
def test_sketch_merge_associative(a, b, c):
    """(a + b) + c == a + (b + c), state-for-state (canonical equality),
    and both orders agree with direct single-sketch ingestion."""
    sa, sb, sc = _sk(a), _sk(b), _sk(c)
    left = sa.merge(sb).merge(sc)
    right = sa.merge(sb.merge(sc))
    assert left == right
    assert left == _sk(a).merge(_sk(b).merge(_sk(c)))
    assert left.count == len(a) + len(b) + len(c)


@given(st.lists(finite, max_size=60), st.lists(finite, max_size=60))
def test_sketch_merge_commutative(a, b):
    assert _sk(a).merge(_sk(b)) == _sk(b).merge(_sk(a))


@given(st.lists(finite, min_size=1, max_size=128),
       st.floats(min_value=0.0, max_value=1.0))
def test_sketch_exact_under_small_n(values, q):
    """At or under max_exact samples every quantile is the exact
    nearest-rank order statistic — no approximation in tests/smokes."""
    sk = _sk(values)
    assert sk.is_exact
    rank = max(1, math.ceil(q * len(values)))
    assert sk.quantile(q) == sorted(values)[rank - 1]


@given(st.lists(st.floats(min_value=1e-3, max_value=1e6,
                          allow_nan=False, allow_infinity=False),
                min_size=129, max_size=400),
       st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=25)
def test_sketch_relative_error_bound(values, q):
    """Past the exact phase, the estimate at any quantile is within
    alpha relative error of the true nearest-rank order statistic
    (positive-value streams: the DDSketch guarantee)."""
    alpha = 0.01
    sk = _sk(values, alpha=alpha)
    assert not sk.is_exact
    rank = max(1, math.ceil(q * len(values)))
    true = sorted(values)[rank - 1]
    got = sk.quantile(q)
    assert abs(got - true) <= alpha * true + 1e-12


def test_sketch_latency_scale_past_exact_phase():
    """Deterministic regression: sub-1.0 samples (the latency-in-seconds
    regime the serving engine actually feeds the sketch) past max_exact
    must honour the alpha relative-error bound.  A sign-mirrored bucket
    index space collides here — positive values < 1.0 have *negative*
    magnitude indices — collapsing every percentile to min."""
    alpha = 0.01
    n = 1000
    values = [0.001 + 0.499 * k / (n - 1) for k in range(n)]  # all in (0, 1)
    sk = _sk(values, alpha=alpha)
    assert not sk.is_exact
    for q in (0.5, 0.95, 0.99):
        rank = max(1, math.ceil(q * n))
        true = sorted(values)[rank - 1]
        got = sk.quantile(q)
        assert abs(got - true) <= alpha * true, (q, got, true)
    # mixed signs with sub-1.0 magnitudes must order correctly too
    mixed = [(-1) ** k * (0.01 + 0.9 * k / 399) for k in range(400)]
    sk2 = _sk(mixed)
    assert not sk2.is_exact
    assert sk2.quantile(0.0) == sk2.min < 0 < sk2.max == sk2.quantile(1.0)
    true_med = sorted(mixed)[math.ceil(0.5 * len(mixed)) - 1]
    got_med = sk2.quantile(0.5)
    assert abs(got_med - true_med) <= 0.01 * abs(true_med)


@given(st.lists(finite, max_size=200))
def test_sketch_serialization_roundtrip(values):
    sk = _sk(values)
    back = QuantileSketch.from_dict(json.loads(json.dumps(sk.to_dict())))
    assert back == sk
    assert back.count == sk.count and back.sum == sk.sum


def test_sketch_rejects_nan_and_bad_params():
    with pytest.raises(ValueError):
        QuantileSketch().add(float("nan"))
    with pytest.raises(ValueError):
        QuantileSketch(alpha=0.0)
    with pytest.raises(ValueError):
        _sk([1.0]).merge(_sk([2.0], alpha=0.5))


def test_sketch_extrema_and_empty():
    sk = QuantileSketch()
    assert sk.quantile(0.5) == 0.0 and sk.mean == 0.0
    sk.update([5.0, -3.0, 0.0] + [1.0] * 200)  # force bucketed phase
    assert not sk.is_exact
    assert sk.min == -3.0 and sk.max == 5.0
    assert sk.quantile(0.0) >= sk.min and sk.quantile(1.0) <= sk.max


# -- 2. metrics registry ------------------------------------------------------


def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.inc("c_total", op="matmul")
    reg.inc("c_total", 2.0, op="matmul")
    reg.set("g", 0.5)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.observe("h", v)
    assert reg.get("c_total", op="matmul") == 3.0
    assert reg.get("g") == 0.5
    assert reg.get("h").count == 4
    snap = reg.snapshot()
    assert snap["c_total"]["kind"] == "counter"
    hcell = snap["h"]["cells"][0]
    assert hcell["count"] == 4 and hcell["p50"] == 2.0
    with pytest.raises(ValueError):
        reg.inc("c_total", -1.0, op="matmul")
    with pytest.raises(ValueError):
        reg.set("c_total", 1.0)  # kind clash


def test_registry_snapshot_reset_restore_isolation():
    reg = MetricsRegistry()
    reg.inc("a_total", 5.0)
    saved = reg.snapshot()
    reg.inc("a_total", 7.0)
    reg.set("b", 1.0)
    reg.reset()
    assert reg.names() == []
    reg.restore(saved)
    assert reg.get("a_total") == 5.0
    assert reg.snapshot() == saved
    reg.reset("a_total")
    assert reg.get("a_total") is None


def test_registry_snapshot_is_json_and_prom_renderable():
    reg = MetricsRegistry()
    reg.inc("spring_kernel_dispatch_total", op="masked_matmul", impl="ref")
    reg.observe("lat_s", 0.25, op="decode")
    snap = json.loads(json.dumps(reg.snapshot()))
    prom = prometheus_from_snapshot(snap)
    assert "# TYPE spring_kernel_dispatch_total counter" in prom
    assert '# TYPE lat_s summary' in prom
    assert 'lat_s{op="decode",quantile="0.5"} 0.25' in prom
    assert "lat_s_count" in prom and "lat_s_sum" in prom
    table = render_snapshot_table(snap)
    assert "spring_kernel_dispatch_total" in table and "p50" in table


def test_dispatch_counters_ride_on_default_registry():
    """The kernel registry's dispatch counters are MetricsRegistry cells
    now; the legacy dispatch_counts()/reset API reads/clears the same
    state, and the conftest fixture isolates it per test."""
    import jax.numpy as jnp

    from repro.kernels import registry
    from repro.kernels.masked_matmul.ops import masked_matmul
    from repro.telemetry import default_registry

    registry.reset_dispatch_counts()
    assert registry.dispatch_counts() == {}
    a = jnp.ones((8, 8)) * jnp.asarray(
        np.random.default_rng(0).random((8, 8)) > 0.5, jnp.float32)
    masked_matmul(a, jnp.ones((8, 8)))
    counts = registry.dispatch_counts()
    assert sum(counts.get("masked_matmul", {}).values()) >= 1
    cell = default_registry().get(
        registry.DISPATCH_METRIC, op="masked_matmul",
        impl=next(iter(counts["masked_matmul"])))
    assert cell is not None and cell >= 1
    registry.reset_dispatch_counts()
    assert registry.dispatch_counts() == {}


# -- 3. span tracer + trace schema -------------------------------------------


def test_tracer_records_and_exports_valid_trace(tmp_path):
    tr = SpanTracer()
    with tr.span("serve.tick", tick=0):
        with tr.span("serve.tick.decode", active=2):
            pass
    tr.instant("admit", rid=1)
    path = tr.write(str(tmp_path / "t.json"), extra_metadata={"run": "test"})
    events = validate_chrome_trace(open(path).read())
    names = [e["name"] for e in events]
    assert set(names) == {"serve.tick", "serve.tick.decode", "admit"}
    complete = [e for e in events if e["ph"] == "X"]
    assert all(e["dur"] >= 0 for e in complete)
    # child closed before parent: appears first, nested inside in time
    decode = next(e for e in complete if e["name"] == "serve.tick.decode")
    tick = next(e for e in complete if e["name"] == "serve.tick")
    assert tick["ts"] <= decode["ts"]
    assert decode["ts"] + decode["dur"] <= tick["ts"] + tick["dur"] + 1e-6


def test_tracer_sampling_is_deterministic_and_tree_scoped():
    tr = SpanTracer(sample_rate=0.5)
    for i in range(10):
        with tr.span("root", i=i):
            with tr.span("child"):
                pass
    events = tr.events()
    roots = [e for e in events if e["name"] == "root"]
    children = [e for e in events if e["name"] == "child"]
    # accumulator: exactly ceil(10 * 0.5) roots, each with its child
    assert len(roots) == 5 and len(children) == 5
    tr2 = SpanTracer(sample_rate=0.5)
    for i in range(10):
        with tr2.span("root", i=i):
            pass
    assert [e["args"]["i"] for e in tr2.events()
            ] == [e["args"]["i"] for e in roots]
    with pytest.raises(ValueError):
        SpanTracer(sample_rate=0.0)


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"no": "traceEvents"})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"name": "x", "ph": "Q"}]})
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [{"name": "x", "ph": "X", "ts": 0.0,
                              "dur": -1.0, "pid": 1, "tid": 1}]})


def test_ambient_scope_activates_and_restores():
    from repro import telemetry

    assert telemetry.tracer() is None
    with telemetry.span("noop"):  # disabled path: shared null span
        pass
    assert telemetry.span("a") is telemetry.span("b")
    with telemetry.scope(TelemetryConfig(enabled=True)) as tr:
        assert telemetry.enabled() and telemetry.tracer() is tr
        with telemetry.span("serve.tick"):
            pass
        assert len(tr) == 1
    assert telemetry.tracer() is None
    with telemetry.scope(None) as tr:
        assert tr is None and not telemetry.enabled()


# -- 4. session parity seal + latency attribution -----------------------------


def _serve_specs(tmp_path):
    from repro.api.sessions import serve_spec
    from repro.api.spec import TelemetrySection

    spec = serve_spec("llama3.2-1b", batch=2, prompt_len=8, gen=4,
                      slots=2, queue=3, mode="quant_sparse")
    spec_on = dataclasses.replace(spec, telemetry=TelemetrySection(
        enabled=True, trace_path=str(tmp_path / "serve_trace.json")))
    return spec, spec_on


@pytest.mark.slow
def test_serve_parity_and_attribution_with_telemetry(tmp_path):
    """The acceptance seal: telemetry on vs off is bit-identical on
    generated tokens; the on-run emits a valid trace with tick-phase
    spans and per-request TTFT/queue/tick attribution."""
    from repro.api.sessions import session_for

    spec, spec_on = _serve_specs(tmp_path)
    out_off = session_for(spec).run()
    out_on = session_for(spec_on).run()
    assert np.array_equal(np.asarray(out_off["generated"]),
                          np.asarray(out_on["generated"]))
    assert "telemetry" not in out_off

    events = validate_chrome_trace(
        open(tmp_path / "serve_trace.json").read())
    names = {e["name"] for e in events}
    assert {"serve.tick", "serve.tick.schedule", "serve.tick.prefill",
            "serve.tick.install", "serve.tick.decode", "serve.tick.sample",
            "serve.tick.repack"} <= names

    for out in (out_off, out_on):  # attribution is always-on engine state
        la = out["latency"]
        for k in ("queue_s", "ttft_s", "token_s"):
            assert set(la[k]) == {"p50", "p95", "p99"}
        assert 0.0 < la["tick_utilization"] <= 1.0
        for r in out["per_request"]:
            assert r["enqueue_tick"] >= 0
            assert r["first_token_tick"] >= r["enqueue_tick"]
            assert r["finish_tick"] >= r["first_token_tick"]
            assert r["decode_ticks"] == r["n_tokens"]
            assert r["ttft_s"] >= r["queue_s"] >= 0.0

    tel = out_on["telemetry"]
    assert tel["spans"] == len(events)
    snap = tel["metrics"]
    assert "spring_serve_tick_utilization" in snap
    assert "spring_kernel_dispatch_total" in snap
    json.dumps(tel)  # must be artifact-safe


@pytest.mark.slow
def test_train_parity_with_telemetry(tmp_path):
    """Train losses bit-identical on vs off; the trace carries the step
    phase taxonomy plus memstash pack/unpack spans."""
    from repro.api.sessions import session_for, train_spec
    from repro.api.spec import TelemetrySection

    spec = train_spec(steps=2, batch=2, seq=16, stash="stash")
    out_off = session_for(spec).run()
    trace = tmp_path / "train_trace.json"
    spec_on = dataclasses.replace(spec, telemetry=TelemetrySection(
        enabled=True, trace_path=str(trace)))
    out_on = session_for(spec_on).run()
    assert out_off["losses"] == out_on["losses"]
    names = {e["name"] for e in validate_chrome_trace(trace.read_text())}
    assert {"train.step", "train.step.data", "train.step.device",
            "train.step.host", "memstash.pack", "memstash.unpack"} <= names


def test_telemetry_spec_section_roundtrip():
    from repro.api.spec import RunSpec, SpecError, build_spec

    spec = build_spec("serve", sets=["telemetry.enabled=true",
                                    "telemetry.sample_rate=0.25"])
    assert spec.telemetry.enabled and spec.telemetry.sample_rate == 0.25
    assert spec.provenance["telemetry.enabled"].startswith("set:")
    back = RunSpec.from_dict(spec.to_dict())
    assert back.telemetry == spec.telemetry
    with pytest.raises(SpecError):
        build_spec("serve", sets=["telemetry.sample_rate=0"]).validate()


def test_report_cli_renders_artifact(tmp_path, capsys):
    from repro.telemetry import report

    reg = MetricsRegistry()
    reg.inc("spring_serve_tokens_total", 12.0)
    artifact = {
        "telemetry": {"metrics": reg.snapshot()},
        "per_request": [{"rid": 0, "queue_s": 0.01, "ttft_s": 0.02,
                         "latency_s": 0.05, "n_tokens": 4,
                         "enqueue_tick": 0, "first_token_tick": 1,
                         "finish_tick": 4}],
    }
    path = tmp_path / "run.json"
    path.write_text(json.dumps(artifact))
    report.main([str(path)])
    text = capsys.readouterr().out
    assert "spring_serve_tokens_total" in text
    assert "0->1->4" in text
    report.main([str(path), "--prom"])
    assert "# TYPE spring_serve_tokens_total counter" in capsys.readouterr().out
    tr = SpanTracer()
    with tr.span("serve.tick"):
        pass
    tpath = tr.write(str(tmp_path / "trace.json"))
    report.main(["--validate-trace", tpath])
    assert "1 events OK" in capsys.readouterr().out
    with pytest.raises(SystemExit):
        report.extract_snapshot({"something": "else"})
