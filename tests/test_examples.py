"""Smoke tests: each runnable example imports and completes ``main(steps=1)``
under the default StepConfig (backward_sparsity="auto") — the examples are
documentation, so they must stay green (ISSUE 3, satellite 4)."""

import importlib.util
import pathlib
import sys

EXAMPLES_DIR = pathlib.Path(__file__).parent.parent / "examples"


def _load(name: str):
    spec = importlib.util.spec_from_file_location(
        f"examples_{name}", EXAMPLES_DIR / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_main_runs(capsys):
    mod = _load("quickstart")
    mod.main(steps=1)
    out = capsys.readouterr().out
    assert "[P1]" in out and "[P2]" in out and "[train]" in out
    assert "bwd dX" in out  # the backward-sparsity demo line


def test_train_lm_main_runs(tmp_path, capsys):
    mod = _load("train_lm")
    res = mod.main(steps=1, argv=["--ckpt-dir", str(tmp_path / "ck")])
    assert res["last_loss"] is not None
    assert "final:" in capsys.readouterr().out


def test_serve_batched_main_plumbs_engine_flags(capsys):
    """ISSUE 4 satellite: --kernel-impl / --greedy / --seed (and the
    engine's --slots/--queue) reach serve_session from the example CLI."""
    mod = _load("serve_batched")
    out = mod.main(argv=[
        "--batch", "2", "--prompt-len", "6", "--gen", "3", "--slots", "2",
        "--queue", "3", "--mode", "quant_sparse", "--kernel-impl", "ref",
        "--greedy", "--seed", "3",
    ])
    assert out["engine"] and out["finite"]
    assert len(out["per_request"]) == 3
    text = capsys.readouterr().out
    assert "tok/s" in text and "kv:" in text
