"""The paper's seven CNNs: forward smoke at tiny resolution + layer-table
sanity against published MAC/param counts."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.spring_ops import QUANT_SPARSE, KeyGen
from repro.models.cnn import PAPER_CNNS, cnn_apply, cnn_init, cnn_layer_table
from repro.models.layers import SpringContext

# (GMACs, Mparams) from ptflops-style published measurements; NAS cells are
# documented simplified approximations (DESIGN.md) -> wide tolerance.
PUBLISHED = {
    "inception_resnet_v2": (13.2, 55.8, 0.3),
    "inception_v3": (5.73, 27.2, 0.3),
    "mobilenet_v2": (0.30, 3.5, 0.2),
    "nasnet_mobile": (0.56, 5.3, 0.8),
    "pnasnet_mobile": (0.59, 5.1, 0.8),
    "resnet152_v2": (11.5, 60.2, 0.2),
    "vgg19": (19.6, 143.7, 0.1),
}


@pytest.mark.parametrize("name", sorted(PAPER_CNNS))
def test_layer_table_close_to_published(name):
    table = cnn_layer_table(PAPER_CNNS[name])
    gmacs = sum(r.macs for r in table) / 1e9
    mparams = sum(r.w_elems for r in table) / 1e6
    ref_g, ref_p, tol = PUBLISHED[name]
    assert abs(gmacs - ref_g) / ref_g <= tol, f"{name} GMACs {gmacs} vs {ref_g}"
    assert abs(mparams - ref_p) / ref_p <= tol, f"{name} params {mparams} vs {ref_p}"


@pytest.mark.parametrize("name", ["vgg19", "mobilenet_v2", "resnet152_v2"])
def test_cnn_forward_smoke(name):
    cnn = PAPER_CNNS[name]
    hw = 64 if name == "vgg19" else 96
    params = cnn_init(jax.random.PRNGKey(0), cnn, input_hw=hw)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, hw, hw, 3))
    logits = cnn_apply(params, cnn, x, SpringContext())
    assert logits.shape == (2, 1000)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_cnn_quant_sparse_mode():
    """Full SPRING path (Q4.16 + SR + mask numerics) through a real CNN."""
    cnn = PAPER_CNNS["mobilenet_v2"]
    params = cnn_init(jax.random.PRNGKey(0), cnn, input_hw=64)
    ctx = SpringContext(cfg=QUANT_SPARSE, keys=KeyGen(jax.random.PRNGKey(2)))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))
    logits = cnn_apply(params, cnn, x, ctx)
    assert bool(jnp.all(jnp.isfinite(logits)))
