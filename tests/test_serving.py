"""Serving parity seal (ISSUE 4, satellite 1).

The continuous-batching engine must be *bit-identical*, per request, to
the pre-refactor static batch path — kept verbatim as
``launch.serve.static_reference_session`` — for a fixed (arch, seed,
mode) triple, across all three numerics modes; and a request's tokens
must be invariant to batch composition (slot count, co-tenants, queueing
order of strangers).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.serve import serve_session, serving_config, static_reference_session
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.train import StepConfig
from repro.serving.engine import ServingEngine

pytestmark = pytest.mark.serving

ARCH = "llama3.2-1b"
BATCH, PROMPT, GEN = 3, 8, 5


def _tokens(out) -> np.ndarray:
    return np.asarray(out["generated"])


@pytest.mark.parametrize("mode", ["dense", "quant", "quant_sparse"])
def test_engine_matches_static_reference(mode):
    """Same arch/seed/mode: engine greedy tokens == static-path tokens,
    bit-identical, even when a 2-slot pool forces mid-flight joins."""
    static = static_reference_session(
        ARCH, reduced=True, batch=BATCH, prompt_len=PROMPT, gen=GEN, mode=mode)
    engine_full = serve_session(
        ARCH, reduced=True, batch=BATCH, prompt_len=PROMPT, gen=GEN, mode=mode)
    engine_tight = serve_session(
        ARCH, reduced=True, batch=BATCH, prompt_len=PROMPT, gen=GEN, mode=mode,
        slots=2)
    np.testing.assert_array_equal(_tokens(engine_full), _tokens(static))
    np.testing.assert_array_equal(_tokens(engine_tight), _tokens(static))
    assert engine_full["finite"] and engine_tight["finite"]


def _engine(step_cfg, params, cfg_view, n_slots, max_len=64):
    return ServingEngine(cfg_view, step_cfg, params=params, n_slots=n_slots,
                         max_len=max_len)


def _run_prompts(view, step_cfg, params, prompts, gen, n_slots, eos=None):
    eng = _engine(step_cfg, params, view, n_slots)
    for i, p in enumerate(prompts):
        eng.submit_prompt(p, gen, seed=100 + i, eos_id=eos)
    out = eng.run()
    return [r["tokens"] for r in out["per_request"]], out


@pytest.fixture(scope="module")
def small_model():
    arch = get_arch(ARCH)
    view = arch.view(reduced=True)
    step_cfg = StepConfig(spring=serving_config("quant_sparse"),
                          optimizer=OptimizerConfig())
    from repro.models.lm import lm_init

    params = lm_init(jax.random.PRNGKey(0), view.config)
    key = jax.random.PRNGKey(3)
    prompts = [
        [int(t) for t in jax.random.randint(jax.random.fold_in(key, i),
                                            (PROMPT + i,), 0, view.config.vocab)]
        for i in range(4)
    ]
    return view, step_cfg, params, prompts


def test_tokens_invariant_to_batch_composition(small_model):
    """A request's tokens don't change when strangers share its batch:
    alone vs 3 co-tenants vs different slot counts, ragged prompt lengths."""
    view, step_cfg, params, prompts = small_model
    alone, _ = _run_prompts(view, step_cfg, params, prompts[:1], GEN, n_slots=2)
    together, _ = _run_prompts(view, step_cfg, params, prompts, GEN, n_slots=4)
    queued, _ = _run_prompts(view, step_cfg, params, prompts, GEN, n_slots=2)
    assert together[0] == alone[0]
    assert queued == together
    # and under a different co-tenant ordering (request 0 admitted last)
    rev, out = _run_prompts(view, step_cfg, params,
                            prompts[1:] + prompts[:1], GEN, n_slots=2)
    assert rev[-1] == alone[0]
    assert out["finite"]


def test_eos_truncates_and_is_included(small_model):
    """A request retires on EOS with exactly min(steps-to-eos, max_tokens)
    tokens, EOS included; co-tenants are unaffected by its early exit."""
    view, step_cfg, params, prompts = small_model
    base, _ = _run_prompts(view, step_cfg, params, prompts[:2], GEN, n_slots=2)
    eos = base[0][2]  # the token request 0 greedily emits at step 3
    got, _ = _run_prompts(view, step_cfg, params, prompts[:2], GEN, n_slots=2,
                          eos=eos)
    assert got[0] == base[0][:3] and got[0][-1] == eos
    # request 1 may legitimately also hit this eos token; only check that
    # what it did emit is the unchanged prefix of its eos-free generation
    assert got[1] == base[1][: len(got[1])]


def test_serving_config_is_deterministic():
    """Serving numerics round to nearest: SR noise is drawn batch-wide,
    which would break batch-composition invariance (DESIGN.md §9)."""
    for mode in ("dense", "quant", "quant_sparse"):
        cfg = serving_config(mode)
        assert cfg.stochastic is False
        assert cfg.mode == mode


def test_one_shot_wrapper_surfaces_engine_metrics():
    out = serve_session(ARCH, reduced=True, batch=2, prompt_len=6, gen=3,
                        mode="quant_sparse", slots=2)
    assert out["engine"] is True
    assert out["generated"].shape == (2, 3)
    assert len(out["per_request"]) == 2
    for r in out["per_request"]:
        assert r["n_tokens"] == 3
        assert r["latency_s"] >= r["queue_s"] >= 0.0
    assert out["decode_steps"] >= 3
    assert 0.0 < out["mean_occupancy"] <= 1.0
    assert out["kv_mean_wire_bytes"] > 0.0
    assert out["kv_traffic_reduction_vs_fp32"] > 1.0


def test_engine_rejects_oversized_request():
    arch = get_arch(ARCH)
    view = arch.view(reduced=True)
    step_cfg = StepConfig(spring=serving_config("dense"),
                          optimizer=OptimizerConfig())
    eng = ServingEngine(view, step_cfg, n_slots=1, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit_prompt(list(range(6)), 4)


def test_sampled_decode_uses_per_request_keys(small_model):
    """Non-greedy decode is a function of the request's own seed: same
    request alone vs batched draws identical tokens."""
    view, step_cfg, params, prompts = small_model

    def run(plist, slots):
        eng = _engine(step_cfg, params, view, slots)
        eng.greedy = False
        for i, p in enumerate(plist):
            eng.submit_prompt(p, GEN, seed=41)  # seed fixed per submission order
        return [r["tokens"] for r in eng.run()["per_request"]]

    alone = run(prompts[:1], 2)
    batched = run(prompts[:1] + prompts[1:3], 3)
    assert batched[0] == alone[0]
