"""CLI parity shims (ISSUE 5 satellite): every pre-redesign flag
spelling resolves to the same RunSpec as its ``--set`` form, with a
DeprecationWarning; and no launcher may carry an argparse option that is
not backed by a RunSpec field (the coverage test the CI spec job runs)."""

import warnings

import pytest

import repro.launch.dryrun as launch_dryrun
import repro.launch.serve as launch_serve
import repro.launch.train as launch_train
from repro.api.cli import OPERATIONAL_OPTIONS, spec_from_args
from repro.api.spec import field_paths

pytestmark = pytest.mark.spec

LAUNCHERS = {
    "train": launch_train,
    "serve": launch_serve,
    "dryrun": launch_dryrun,
}


def _spec(mod, run, argv, warn=True):
    args = mod.build_parser().parse_args(argv)
    return spec_from_args(run, args, mod.LEGACY_FLAGS, warn=warn)


# -- legacy spelling == --set spelling, with a DeprecationWarning ------------

PARITY_CASES = [
    ("train", ["--stash", "stash"], ["--set", "memstash.policy=stash"]),
    ("train", ["--kernel-impl", "ref,ssd_scan=jnp"],
     ["--set", "kernels.policy=ref,ssd_scan=jnp"]),
    ("train", ["--backward-sparsity", "jnp"],
     ["--set", "sparsity.backward=jnp"]),
    ("train", ["--arch", "qwen2-7b", "--reduced", "--steps", "7",
               "--batch", "2", "--seq", "16", "--mode", "quant",
               "--lr", "0.01", "--fixed-point-weights",
               "--ckpt-dir", "/tmp/x", "--ckpt-every", "5"],
     ["--set", "arch.id=qwen2-7b", "--set", "arch.reduced=true",
      "--set", "train.steps=7", "--set", "shape.batch=2",
      "--set", "shape.seq=16", "--set", "numerics.mode=quant",
      "--set", "optimizer.lr=0.01",
      "--set", "numerics.fixed_point_weights=true",
      "--set", "train.ckpt_dir=/tmp/x", "--set", "train.ckpt_every=5"]),
    ("serve", ["--slots", "2", "--queue", "6"],
     ["--set", "serving.slots=2", "--set", "serving.queue=6"]),
    ("serve", ["--sample", "--seed", "3", "--static"],
     ["--set", "serving.greedy=false", "--set", "seeds.seed=3",
      "--set", "serving.static=true"]),
    ("serve", ["--kernel-impl", "ref", "--mode", "quant_sparse",
               "--prompt-len", "6", "--gen", "3", "--batch", "2"],
     ["--set", "kernels.policy=ref", "--set", "numerics.mode=quant_sparse",
      "--set", "shape.prompt_len=6", "--set", "shape.gen=3",
      "--set", "shape.batch=2"]),
    ("dryrun", ["--arch", "qwen2-7b", "--shape", "train_4k",
                "--mesh", "multi", "--mode", "quant_sparse",
                "--backward-sparsity", "ref", "--kernel-impl", "ref",
                "--layout", "fsdp", "--seq-parallel", "--cache-int8",
                "--quant-opt", "--variant", "v1", "--microbatch", "4",
                "--probe-density", "0.25", "--no-unrolled-cost",
                "--bf16-logits", "--remat-policy", "block_io"],
     ["--set", "arch.id=qwen2-7b", "--set", "shape.cell=train_4k",
      "--set", "shape.mesh=multi", "--set", "numerics.mode=quant_sparse",
      "--set", "sparsity.backward=ref", "--set", "kernels.policy=ref",
      "--set", "shape.layout=fsdp", "--set", "shape.seq_parallel=true",
      "--set", "serving.int8_cache=true", "--set", "dryrun.quant_opt=true",
      "--set", "dryrun.variant=v1", "--set", "shape.microbatch=4",
      "--set", "sparsity.probe_density=0.25",
      "--set", "dryrun.cost_unrolled=false",
      "--set", "arch.bf16_logits=true",
      "--set", "arch.remat_policy=block_io"]),
]


@pytest.mark.parametrize("run,legacy_argv,set_argv", PARITY_CASES,
                         ids=[f"{r}-{i}" for i, (r, _, _) in
                              enumerate(PARITY_CASES)])
def test_legacy_flags_resolve_to_same_spec_with_warning(run, legacy_argv,
                                                        set_argv):
    mod = LAUNCHERS[run]
    with pytest.warns(DeprecationWarning, match="--set"):
        legacy = _spec(mod, run, legacy_argv)
    new = _spec(mod, run, set_argv)
    assert legacy == new
    assert legacy.spec_hash() == new.spec_hash()
    # provenance still distinguishes the layers
    assert any(v.startswith("legacy:") for v in legacy.provenance.values())
    assert any(v.startswith("set:") for v in new.provenance.values())


def test_legacy_remat_policy_full_is_a_noop():
    """Preserved quirk: the old dryrun --remat-policy full never replaced
    the arch config, so the shim must not either."""
    with pytest.warns(DeprecationWarning):
        legacy = _spec(launch_dryrun, "dryrun", ["--remat-policy", "full"])
    assert legacy == _spec(launch_dryrun, "dryrun", [])
    assert legacy.arch.remat_policy == ""


def test_paired_boolean_flags_last_on_command_line_wins():
    """--greedy/--sample share one argparse dest (like the old parser),
    so the last spelling typed wins regardless of declaration order."""
    with pytest.warns(DeprecationWarning):
        spec = _spec(launch_serve, "serve", ["--sample", "--greedy"])
    assert spec.serving.greedy is True
    with pytest.warns(DeprecationWarning):
        spec = _spec(launch_serve, "serve", ["--greedy", "--sample"])
    assert spec.serving.greedy is False
    assert spec.provenance["serving.greedy"] == "legacy:--sample"


def test_dryrun_bare_invocation_still_errors(capsys):
    """The pre-RunSpec dryrun CLI required --arch/--shape; a bare
    invocation must not silently compile the default cell."""
    with pytest.raises(SystemExit) as exc:
        launch_dryrun.main([])
    assert exc.value.code == 2
    assert "arch.id" in capsys.readouterr().err


def test_dryrun_explain_reports_the_executed_spec(capsys):
    """--explain must show the spec the run would use (arch.reduced=None
    resolves run-conditionally in the resolver, so CLI and API agree) —
    and still enforce the arch.id/shape.cell guard."""
    rc = launch_dryrun.main(["--set", "arch.id=llama3.2-1b",
                             "--set", "shape.cell=decode_32k", "--explain"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "arch.reduced = None  [default]" in out
    with pytest.raises(SystemExit):  # guard still applies under --explain
        launch_dryrun.main(["--explain"])


def test_set_wins_over_legacy_flag():
    with pytest.warns(DeprecationWarning):
        spec = _spec(launch_train, "train",
                     ["--mode", "quant", "--set", "numerics.mode=dense"])
    assert spec.numerics.mode == "dense"


def test_serve_cli_base_layer_keeps_historical_batch():
    """The serve adapter pins its pre-RunSpec default (--batch 4) as a
    base layer; file/env/CLI layers still override it."""
    args = launch_serve.build_parser().parse_args([])
    spec = spec_from_args("serve", args, launch_serve.LEGACY_FLAGS,
                          base=launch_serve.CLI_BASE)
    assert spec.shape.batch == 4
    assert spec.provenance["shape.batch"] == "launcher-default"
    args = launch_serve.build_parser().parse_args(["--set", "shape.batch=6"])
    assert spec_from_args("serve", args, launch_serve.LEGACY_FLAGS,
                          base=launch_serve.CLI_BASE).shape.batch == 6


def test_no_warning_without_legacy_flags(recwarn):
    _spec(launch_train, "train", ["--set", "train.steps=3"])
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]


# -- coverage: every launcher option is RunSpec-backed -----------------------


@pytest.mark.parametrize("name,mod", sorted(LAUNCHERS.items()))
def test_launcher_options_all_backed_by_runspec_fields(name, mod):
    """The CI spec job's growth guard: a launcher may only carry
    operational options (--spec/--set/--json/--out/...) and declared
    LegacyFlag shims, each shim pointing at a real RunSpec field — new
    knobs must become RunSpec fields first."""
    legacy_options = {lf.option for lf in mod.LEGACY_FLAGS}
    for lf in mod.LEGACY_FLAGS:
        assert lf.path in field_paths(), (name, lf.option, lf.path)
    ap = mod.build_parser()
    for action in ap._actions:
        for opt in action.option_strings:
            if not opt.startswith("--"):
                continue
            assert opt in OPERATIONAL_OPTIONS or opt in legacy_options, (
                f"{name}: argparse option {opt} is not backed by a RunSpec "
                f"field — add a field to repro.api.spec and declare a "
                f"LegacyFlag (or use --set)")


def test_examples_flags_are_runspec_backed():
    """The examples' convenience flags must also map onto RunSpec fields
    (they share the LegacyFlag machinery, minus the deprecation)."""
    import importlib.util
    import pathlib
    import sys

    for name in ("serve_batched", "train_lm"):
        path = pathlib.Path(__file__).parent.parent / "examples" / f"{name}.py"
        ispec = importlib.util.spec_from_file_location(f"exflags_{name}", path)
        mod = importlib.util.module_from_spec(ispec)
        sys.modules[ispec.name] = mod
        ispec.loader.exec_module(mod)
        for lf in mod.FLAGS:
            assert lf.path in field_paths(), (name, lf.option)
